"""Cluster clairvoyant placement: one cross-rank plan, one bucket GET per key.

The per-rank oracle (``repro.oracle.planner``) removes every *local*
inefficiency — rounds are deadline-ordered, capacity-windowed and
residency-filtered — but each rank still plans in isolation, so a key that
appears in several ranks' epochs is bucket-fetched by several ranks.  In
the shared-shuffle regime (every rank streams the full dataset) that
multiplies cluster-wide Class B by the world size.  Hoard (PAPERS.md)
shows the fix at the placement level — partition the dataset across node
caches and serve everyone over the peer tier — and NoPFS shows the access
orders are exactly knowable ahead of time.  This module combines them:

  * :class:`ClusterPlacementPlanner` replays every rank's epoch order
    (the same seeded-sampler replay as ``AccessOracle``) and assigns each
    key exactly ONE **owner**: the rank whose first use of the key is the
    cluster-wide earliest (ties broken by rank — deterministic, so both
    projections compute the identical partition).  Owning rank r means
    "r bucket-fetches the key; everyone else peer-pulls it from r".
  * :class:`PlacementPrefetchPlanner` is the per-rank epoch planner it
    hands out: the *announce schedule is inherited unchanged* from
    ``OraclePrefetchPlanner`` (deadline order, capacity window, ramp or
    cost sizing, residency filter), it merely carries the rank's ``owned``
    set.  The actual bucket-vs-peer-vs-defer split happens where fetches
    are billed — the shared ``LockstepPrefetchService`` partitions each
    round by ownership (``set_placement``), so both projections execute
    the identical event code.

Why the owner's fetch precedes every consumer's first use (uncapped
capacity): the owner's first use of a key is, by construction, the
cluster-wide earliest, and ``announce_schedule`` announces each key at or
before its own consume position — so the owner's fetch round is issued at
or before the earliest use anywhere.  A consumer announcing the key while
that fetch is still in flight defers it (the cluster-shared ``in_flight``
set is the signal) and retries at its next announce point, by then a peer
hit.  Under capacity pressure the owner may already have *evicted* its
copy — neither resident nor in flight — and then the consumer bucket-
fetches the key itself: a planned duplicate on a cheap amortized bulk GET
instead of a guaranteed serial demand GET at consume time.  The invariant
is "never a duplicate bucket GET while a copy is resident or in flight";
with capacity to hold the plan, that is exactly one GET per key.

Pure planning logic: no clocks, no I/O.  Both projections instantiate
planners through the one ``repro.oracle.planner.planner_for`` factory
(``policy="cluster-oracle"``), keeping placement specs inside the exact
``==`` parity domain (docs/PARITY.md).
"""
from __future__ import annotations

from typing import Callable, Dict, FrozenSet, List, Optional, Sequence

from repro.oracle.oracle import replayable
from repro.oracle.planner import OraclePrefetchPlanner, RoundCostModel

class PlacementPrefetchPlanner(OraclePrefetchPlanner):
    """A rank's slice of the cluster plan: the per-rank oracle schedule
    plus the frozen set of keys this rank owns (bucket-fetches).

    Deliberately *nothing else* changes relative to the per-rank planner:
    the announce positions, round sizes and residency filtering are
    inherited verbatim, so the clairvoyant deadline guarantees carry over
    and the only new behaviour is where each key's bytes come from.
    """

    #: Flight-recorder provenance (ISSUE 10): ownership-partitioned rounds.
    #: The per-key outcomes (owned / planned-duplicate / deferred / retry)
    #: are stamped by the shared service partition itself.
    provenance = "cluster-oracle"

    def __init__(
        self,
        order: Sequence[int],
        owned: FrozenSet[int],
        capacity: Optional[int] = None,
        resident: Optional[Callable[[int], bool]] = None,
        sizing: str = "ramp",
        cost_model: Optional[RoundCostModel] = None,
        in_flight: Optional[set] = None,
    ):
        super().__init__(
            order, capacity=capacity, resident=resident, sizing=sizing, cost_model=cost_model
        )
        #: Keys this rank bucket-fetches; every other key in its order is
        #: peer-pulled (or deferred until a peer holds it).  Drivers hand
        #: this to ``LockstepPrefetchService.set_placement`` at epoch start.
        self.owned = frozenset(owned)
        #: The cluster-shared issued-but-not-inserted key set (one per
        #: ``ClusterPlacementPlanner``), handed to ``set_placement`` along
        #: with ``owned`` so every rank's service sees peers' fetches.
        self.in_flight = in_flight


class ClusterPlacementPlanner:
    """The cross-rank planner: replay all epoch orders, partition ownership.

    Constructed from the per-rank samplers both projections already build
    identically (``DataPlaneSpec.build_samplers`` / ``simulate_cluster``'s
    ``samplers=``).  Requires every sampler to be replayable — a sampler
    whose order depends on runtime cluster state (``locality``) cannot be
    planned for before the epoch runs, and the planner refuses rather than
    partitioning a wrong future.
    """

    def __init__(self, samplers: Sequence):
        if not samplers:
            raise ValueError("ClusterPlacementPlanner needs at least one sampler")
        for rank, sampler in enumerate(samplers):
            if not replayable(sampler):
                raise ValueError(
                    "cluster-oracle placement requires replayable samplers; "
                    f"rank {rank}'s sampler ({type(sampler).__name__}) depends "
                    "on runtime cache state"
                )
        self.samplers = list(samplers)
        self.world = len(self.samplers)
        self._owned: Dict[int, List[FrozenSet[int]]] = {}
        self._orders: Dict[int, List[List[int]]] = {}
        #: Keys with a bucket fetch issued but not yet inserted, anywhere in
        #: the cluster — the services' shared "copy on its way" signal.
        #: Deliberately the ONLY cross-rank runtime state placement adds:
        #: eviction stays per-rank Belady/FIFO (cluster-wide retention of
        #: owned keys was measured and rejected — it displaces the rank's
        #: own announced window, turning cheap planned duplicates into
        #: serial demand misses).
        self.in_flight: set = set()

    def epoch_orders(self, epoch: int) -> List[List[int]]:
        """Every rank's exact order for ``epoch`` (the AccessOracle replay:
        temporarily move the sampler's epoch, restore after); memoized."""
        cached = self._orders.get(epoch)
        if cached is not None:
            return cached
        orders: List[List[int]] = []
        for sampler in self.samplers:
            saved = sampler.epoch
            try:
                sampler.set_epoch(epoch)
                orders.append(list(sampler.indices()))
            finally:
                sampler.set_epoch(saved)
        self._orders[epoch] = orders
        # Keep the memo bounded: ownership only ever re-reads the current
        # epoch (the previous one is kept for boundary stragglers).
        for stale in [e for e in self._orders if e < epoch - 1]:
            del self._orders[stale]
        return orders

    def owned_sets(self, epoch: int) -> List[FrozenSet[int]]:
        """The epoch's ownership partition: ``result[r]`` is the set of
        keys rank ``r`` bucket-fetches.  Each key in the union of orders
        appears in exactly one set — the rank whose first use of it is the
        cluster-wide earliest, ties to the lowest rank (min over ranks of
        ``(first_use_position, rank)``).  Memoized per epoch; pure function
        of the seeded samplers, so both projections agree exactly."""
        cached = self._owned.get(epoch)
        if cached is not None:
            return cached
        best: Dict[int, tuple] = {}  # key -> (first_use_position, rank)
        for rank, order in enumerate(self.epoch_orders(epoch)):
            seen = set()
            for pos, key in enumerate(order):
                if key in seen:
                    continue
                seen.add(key)
                claim = (pos, rank)
                if key not in best or claim < best[key]:
                    best[key] = claim
        owned: List[set] = [set() for _ in range(self.world)]
        for key, (_, rank) in best.items():
            owned[rank].add(key)
        result = [frozenset(s) for s in owned]
        self._owned[epoch] = result
        for stale in [e for e in self._owned if e < epoch - 1]:
            del self._owned[stale]
        return result

    def planner(
        self,
        rank: int,
        order: Sequence[int],
        *,
        capacity: Optional[int] = None,
        resident: Optional[Callable[[int], bool]] = None,
        sizing: str = "ramp",
        cost_model: Optional[RoundCostModel] = None,
    ) -> PlacementPrefetchPlanner:
        """Rank ``rank``'s epoch planner (the ``planner_for`` entry point).

        The epoch is read off the rank's sampler — by the time either
        projection builds its planner the sampler is already positioned at
        the epoch being run, and ``order`` is that sampler's realized
        order, so the replayed partition matches it exactly."""
        epoch = self.samplers[rank].epoch
        return PlacementPrefetchPlanner(
            order,
            owned=self.owned_sets(epoch)[rank],
            capacity=capacity,
            resident=resident,
            sizing=sizing,
            cost_model=cost_model,
            in_flight=self.in_flight,
        )
