"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (the container target) and False on
real TPU — pass explicitly to override.  These are the functions the model
zoo calls when ``use_pallas`` is enabled; each has a pure-jnp oracle in
kernels/ref.py with identical signature/semantics.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ssd import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
):
    """Blocked online-softmax attention. q (B,Sq,H,hd); k/v (B,Sk,KV,hd)."""
    if interpret is None:
        interpret = _default_interpret()
    return _flash(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128, interpret: Optional[bool] = None):
    """Mamba-2 SSD chunked scan. Returns (y (B,S,H,P), state (B,H,P,N))."""
    if interpret is None:
        interpret = _default_interpret()
    return _ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=interpret)
