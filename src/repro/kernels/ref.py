"""Pure-jnp oracles for the Pallas kernels (same signatures as ops.py).

These delegate to the model zoo's XLA reference implementations — the
kernels and the models literally share one definition of the math, so a
kernel<->ref allclose is also a kernel<->model allclose.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models.layers import AttnMask, plain_attention
from repro.models.ssm import ssd_reference


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None):
    return plain_attention(q, k, v, AttnMask(causal=causal, window=window))


def ssd_scan(x, dt, A, Bm, Cm, D):
    """Sequential-scan oracle; returns (y, final_state)."""
    y = ssd_reference(
        x.astype(jnp.float32),
        dt.astype(jnp.float32),
        A.astype(jnp.float32),
        Bm.astype(jnp.float32),
        Cm.astype(jnp.float32),
        D.astype(jnp.float32),
    )
    # final state: recompute by stepping (oracle-grade, O(S))
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(S):
        decay = jnp.exp(dt[:, t].astype(jnp.float32) * A.astype(jnp.float32))
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t].astype(jnp.float32), Bh[:, t], x[:, t].astype(jnp.float32)
        )
    return y.astype(x.dtype), state
