"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (batch, heads, S/chunk) with the chunk dimension innermost and
sequential ("arbitrary") — the (P, N) SSD state lives in VMEM scratch and
is carried across chunk steps, exactly the inter-chunk recurrence of the
SSD algorithm.  Per step the kernel does four MXU matmuls per head:

    cb   = C  B^T                (Q,N)x(N,Q)   intra-chunk scores
    y    = (cb * L * dt) x       (Q,Q)x(Q,P)   intra-chunk output
    y   += (C S^T) * exp(a_cum)  (Q,N)x(N,P)   inter-chunk output
    S'   = exp(a_tot) S + x^T(w*B)  (P,Q)x(Q,N) state update

VMEM working set per step: x (Q,P) + B,C (Q,N) + state (P,N) f32 + the
(Q,Q) decay matrix — with Q=128, P=64, N=128 that is ~260 KB, comfortably
inside the ~16 MB VMEM budget with double buffering.

Heads are gridded individually (block_h == 1): every matmul above is then a
clean 2-D MXU op; B/C index maps select the head's group (G | H), so grouped
B/C are never materialized per head in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _ssd_kernel(
    x_ref,  # (Q, P)   this (b, h, chunk)'s inputs
    dt_ref,  # (Q, 1)
    A_ref,  # (1, 1)   per-head decay scalar
    B_ref,  # (Q, N)
    C_ref,  # (Q, N)
    D_ref,  # (1, 1)
    y_ref,  # (Q, P)   output
    st_ref,  # (P, N)  final-state output (written on last chunk)
    state,  # VMEM scratch (P, N) f32: the carried SSD state
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q, 1)
    A = A_ref[0, 0].astype(jnp.float32)
    Bm = B_ref[0, 0].astype(jnp.float32)  # (Q, N)
    Cm = C_ref[0, 0].astype(jnp.float32)

    a = dt * A  # (Q, 1) log-decay per step
    a_cum = jnp.cumsum(a, axis=0)  # (Q, 1)
    a_tot = a_cum[chunk - 1, 0]

    # intra-chunk: L[i,j] = exp(a_i - a_j) for i >= j
    seg = a_cum - a_cum.reshape(1, chunk)  # (Qi, Qj)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Qi, Qj)
    M = cb * L * dt.reshape(1, chunk)
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # inter-chunk: y_i += exp(a_cum_i) * C_i . S^T
    cs = jax.lax.dot_general(
        Cm, state[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)
    y = y + cs * jnp.exp(a_cum)

    # state update: S' = exp(a_tot) S + x^T (w * B), w = exp(a_tot - a_cum) dt
    w = jnp.exp(a_tot - a_cum) * dt  # (Q, 1)
    su = jax.lax.dot_general(
        x, Bm * w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    state[...] = state[...] * jnp.exp(a_tot) + su

    y_ref[0, 0] = (y + x * D_ref[0, 0]).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def emit_state():
        st_ref[0, 0] = state[...].astype(st_ref.dtype)


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    D: jax.Array,  # (H,)
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """pl.pallas_call wrapper. Returns (y (B,S,H,P), final_state (B,H,P,N)).

    S must be a chunk multiple (callers pad, as models/ssm.py does).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xg = x.transpose(0, 2, 1, 3)  # (B, H, S, P)
    dtg = dt.transpose(0, 2, 1)[..., None]  # (B, H, S, 1)
    Bg = Bm.transpose(0, 2, 1, 3)  # (B, G, S, N)
    Cg = Cm.transpose(0, 2, 1, 3)
    A2 = A.reshape(H, 1)
    D2 = D.reshape(H, 1)

    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c, rep=rep: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c, rep=rep: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xg, dtg, A2, Bg, Cg, D2)
    return y.transpose(0, 2, 1, 3), st
