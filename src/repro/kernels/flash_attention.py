"""Pallas TPU flash attention (causal / sliding-window / GQA).

TPU-native tiling: the grid is (batch*q_heads, Sq/block_q, Sk/block_k) with
the KV dimension innermost and sequential ("arbitrary"), so the running
online-softmax statistics (m, l) and the output accumulator live in VMEM
scratch across KV steps.  BlockSpec index maps stream one (block_q, hd)
Q-tile and one (block_k, hd) KV-tile into VMEM per step; GQA is expressed
in the K/V index maps (q head h reads kv head h // G) so grouped KV is
never materialized per-q-head in HBM.

Block shapes are the VMEM working set:  f32 scratch (block_q·hd + 2·block_q)
+ tiles (block_q + 2·block_k)·hd·2B.  The defaults (block_q=block_k=128,
MXU-aligned) use ~200 KB of ~16 MB VMEM, leaving room for double buffering.

Fully-masked KV tiles (causal: k-tile entirely after the q-tile; SWA:
k-tile entirely outside the window) are skipped with @pl.when — this is
what makes SWA attention O(S·w) instead of O(S²) at the kernel level.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (block_q, hd), (block_k, hd), (block_k, hd)
    o_ref,  # (block_q, hd)
    m_scr, l_scr, acc_scr,  # VMEM scratch: (block_q, 1), (block_q, 1), (block_q, hd)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    n_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # tile-level skip: entirely above the diagonal / outside the window
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        # newest key this tile could see: q_end; oldest: q_start - window + 1
        needed = jnp.logical_and(needed, k_start + block_k > q_start - window + 1)

    @pl.when(needed)
    def compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_k)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (block_q, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def finish():
        # rows with no valid key (can't happen for causal self-attn) -> 0
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """pl.pallas_call wrapper.  Sq/Sk are padded to block multiples; GQA via
    index maps.  interpret=True (default here) runs the kernel body in
    Python on CPU — the container has no TPU; on hardware pass False."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        # padded keys must never win the max: rely on causal mask (padded
        # q-rows are sliced off; padded k-cols are masked because kpos>qpos
        # for causal). For non-causal (encoder) we mask via window=None and
        # explicit validity below.
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    n_q, n_k = Sq_p // block_q, Sk_p // block_k

    if not causal and pad_k:
        raise ValueError("non-causal flash requires Sk % block_k == 0")

    # layout: fold head into leading grid dim; block over (S, hd)
    qg = q.transpose(0, 2, 1, 3).reshape(B * H, Sq_p, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk_p, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk_p, hd)

    grid = (B * H, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=hd ** -0.5,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qg, kg, vg)
    out = out.reshape(B, H, Sq_p, hd).transpose(0, 2, 1, 3)
    return out[:, :Sq]
