# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Version-compat shims for the Pallas TPU API.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across JAX releases; this environment ships the older
spelling.  Kernels import :func:`tpu_compiler_params` instead of touching
either class directly, so they lower on both API generations.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def tpu_compiler_params(**kwargs):
    """Construct the TPU compiler-params object under either JAX spelling."""
    return _COMPILER_PARAMS_CLS(**kwargs)
